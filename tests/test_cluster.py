"""Round-aware cluster process tests (core/cluster.py + the rounds axis of
the fused MC engine).

Covers the ISSUE-2 acceptance points:
  (a) the DelayProcess API: shapes, state threading, hashability (engine
      cache keys), the IIDProcess compatibility shim;
  (b) zero-correlation parity — a homogeneous IIDProcess pushed through the
      rounds engine reproduces the single-round engine's mean completion
      times within MC tolerance;
  (c) statistical structure: Markov straggler persistence shows up as
      lag-1 autocorrelation and vanishes at persistence=0 (recovering the
      i.i.d. bimodal marginal), heterogeneous worker scales order the
      per-worker means.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (AR1Process, BimodalStragglerDelays, DelayModel,
                        IIDProcess, MarkovRegimeProcess, as_process,
                        cyclic_to_matrix, ec2_cluster, heterogeneous_scales,
                        lb_spec, scenario1, sweep, sweep_rounds, to_spec)


N, R = 6, 2


def _rounds_tensor(process, rounds=8, trials=64, n=N, r=R, seed=0):
    T1, T2 = process.sample_rounds(jax.random.PRNGKey(seed), trials, n, r,
                                   rounds)
    assert T1.shape == T2.shape == (rounds, trials, n, r)
    return np.asarray(T1), np.asarray(T2)


# ------------------------------ (a) API --------------------------------------

@pytest.mark.parametrize("process", [
    IIDProcess(scenario1()),
    MarkovRegimeProcess(base=scenario1(), persistence=0.8),
    AR1Process(base=scenario1(), rho=0.7, sigma=0.3),
    ec2_cluster(N, spread=2.0),
])
def test_process_shapes_and_positivity(process):
    T1, T2 = _rounds_tensor(process)
    assert (T1 > 0).all() and (T2 > 0).all()


def test_processes_are_hashable_cache_keys():
    a = ec2_cluster(N, spread=2.0)
    b = ec2_cluster(N, spread=2.0)
    assert hash(a) == hash(b) and a == b
    assert hash(IIDProcess(scenario1())) == hash(IIDProcess(scenario1()))


def test_as_process_shim():
    m = scenario1()
    p = as_process(m)
    assert isinstance(p, IIDProcess) and p.model is m
    assert as_process(p) is p
    assert isinstance(m.as_process(), IIDProcess)
    with pytest.raises(TypeError):
        as_process(object())


def test_state_threads_and_init_is_stationary():
    proc = MarkovRegimeProcess(base=scenario1(), p_slow=0.4,
                               persistence=0.9, slow=4.0)
    keys = jax.random.split(jax.random.PRNGKey(0), 500)
    state = proc.init(keys, N)
    assert state.shape == (500, N) and state.dtype == bool
    frac0 = float(state.mean())
    state2, T1, _ = proc.step(state, keys, N, R)
    frac1 = float(state2.mean())
    # stationary chain: slow fraction stays ~p_slow after a transition
    assert abs(frac0 - 0.4) < 0.08 and abs(frac1 - 0.4) < 0.08
    assert not np.array_equal(np.asarray(state), np.asarray(state2))


def test_parameter_validation():
    with pytest.raises(ValueError):
        MarkovRegimeProcess(p_slow=1.5)
    with pytest.raises(ValueError):
        MarkovRegimeProcess(persistence=1.2)
    with pytest.raises(ValueError):
        AR1Process(rho=1.0)
    with pytest.raises(ValueError):
        heterogeneous_scales(4, spread=0.5)


# ---------------------- (b) zero-correlation parity --------------------------

def test_zero_correlation_parity_with_single_round_engine():
    """The tentpole's compatibility guarantee: a homogeneous, zero-
    correlation DelayProcess through the rounds engine reproduces the
    single-round engine's mean completion times within MC tolerance."""
    n, r, k, trials = 8, 3, 6, 6000
    m = scenario1()
    specs = [to_spec("cs", cyclic_to_matrix(n, r)), lb_spec(r)]
    single = sweep(specs, m, n, trials=trials, seed=0, ks=k)
    multi = sweep_rounds(specs, IIDProcess(m), n, rounds=4, k=k,
                         trials=trials, seed=0)
    for name in ("cs", "lb"):
        ref = single.at_k(name, k)
        got = multi.per_round[name]
        tol = 5 * (multi.stderr[name] + float(single.stderr[name][0]))
        assert (np.abs(got - ref) < tol).all(), (name, got, ref)
        # and rounds are exchangeable: no drift across the round axis
        assert got.std() < 3 * multi.stderr[name].mean()


def test_markov_zero_persistence_matches_bimodal_marginal():
    p0 = MarkovRegimeProcess(base=scenario1(), p_slow=0.3, persistence=0.0,
                             slow=5.0)
    T1p, _ = _rounds_tensor(p0, rounds=4, trials=800, seed=1)
    bim = BimodalStragglerDelays(base=scenario1(), p_straggle=0.3, slow=5.0)
    T1b, _ = bim.sample(jax.random.PRNGKey(2), 3200, N, R)
    mp, mb = T1p.mean(), float(np.asarray(T1b).mean())
    assert abs(mp - mb) / mb < 0.05


# ----------------------- (c) statistical structure ---------------------------

def test_markov_persistence_is_temporal_correlation():
    def lag1(persistence):
        proc = MarkovRegimeProcess(base=scenario1(), p_slow=0.25,
                                   persistence=persistence, slow=8.0)
        T1, _ = _rounds_tensor(proc, rounds=12, trials=256, seed=3)
        m = T1.mean(-1)                       # (rounds, trials, n)
        a, b = m[:-1].reshape(-1), m[1:].reshape(-1)
        return float(np.corrcoef(a, b)[0, 1])

    assert lag1(0.95) > 0.6
    assert abs(lag1(0.0)) < 0.1


def test_ar1_drift_and_sigma0_recovers_base():
    proc = AR1Process(base=scenario1(), rho=0.9, sigma=0.5)
    T1, _ = _rounds_tensor(proc, rounds=12, trials=256, seed=4)
    m = T1.mean(-1)
    a, b = m[:-1].reshape(-1), m[1:].reshape(-1)
    assert float(np.corrcoef(a, b)[0, 1]) > 0.5
    # sigma=0 recovers the base model in distribution (keys are split
    # differently, so compare moments, not bits)
    flat = AR1Process(base=scenario1(), rho=0.9, sigma=0.0)
    T1f, _ = _rounds_tensor(flat, rounds=3, trials=800, seed=5)
    base, _ = _rounds_tensor(IIDProcess(scenario1()), rounds=3, trials=800,
                             seed=6)
    assert abs(T1f.mean() - base.mean()) / base.mean() < 0.02
    assert abs(T1f.std() - base.std()) / base.std() < 0.1


def test_heterogeneous_scales_order_worker_means():
    scale = heterogeneous_scales(N, spread=4.0, seed=0)
    assert abs(float(np.exp(np.mean(np.log(scale)))) - 1.0) < 1e-6
    proc = MarkovRegimeProcess(base=scenario1(), worker_scale=scale,
                               p_slow=0.0, persistence=0.0, slow=1.0)
    T1, _ = _rounds_tensor(proc, rounds=4, trials=600, seed=6)
    worker_means = T1.mean(axis=(0, 1, 3))
    assert (np.argsort(worker_means) == np.argsort(scale)).all()


def test_homogeneous_scales_trivial():
    assert heterogeneous_scales(5, spread=1.0) == (1.0,) * 5

"""StragglerAggregator + RoundSpec property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import RoundSpec, StragglerAggregator, scenario1


class TestRoundSpec:
    def test_validation(self):
        with pytest.raises(ValueError):
            RoundSpec(n=4, r=2, k=5)
        with pytest.raises(ValueError):
            RoundSpec(n=4, r=5, k=2)
        with pytest.raises(ValueError):
            RoundSpec(n=4, r=0, k=2)

    def test_to_matrix_schedules(self):
        for sched in ("cs", "ss", "block"):
            C = RoundSpec(n=6, r=3, k=4, schedule=sched).to_matrix()
            assert C.shape == (6, 3)
        C = RoundSpec(n=6, r=6, k=4, schedule="ra").to_matrix()
        assert C.shape == (6, 6)


class TestAggregator:
    def test_round_mask_and_combine(self):
        spec = RoundSpec(n=4, r=2, k=3, schedule="cs")
        agg = StragglerAggregator(spec, scenario1())
        w, t = agg.round_mask(jax.random.PRNGKey(0))
        assert w.shape == (4, 2)
        assert np.isclose(float(w.sum()), 3.0, atol=1e-5)
        grads = {"a": jnp.ones((4, 2, 3)), "b": jnp.ones((4, 2))}
        out = agg.combine(grads, w)
        # sum of weights / k = 1 -> combined grad of all-ones is 1
        np.testing.assert_allclose(np.asarray(out["a"]), 1.0, rtol=1e-5)
        np.testing.assert_allclose(np.asarray(out["b"]), 1.0, rtol=1e-5)

    def test_expected_completion_positive_and_orders(self):
        m = scenario1()
        fast = StragglerAggregator(RoundSpec(n=8, r=4, k=4), m)
        slow = StragglerAggregator(RoundSpec(n=8, r=4, k=8), m)
        key = jax.random.PRNGKey(1)
        tf = fast.expected_completion(key)
        ts = slow.expected_completion(key)
        assert 0 < tf < ts

    @settings(deadline=None, max_examples=20)
    @given(st.integers(2, 8), st.data())
    def test_property_combine_unbiased_weighting(self, n, data):
        r = data.draw(st.integers(1, n))
        k = data.draw(st.integers(1, n))
        sched = data.draw(st.sampled_from(["cs", "ss"]))
        spec = RoundSpec(n=n, r=r, k=k, schedule=sched)
        agg = StragglerAggregator(spec, scenario1())
        w, _ = agg.round_mask(jax.random.PRNGKey(data.draw(
            st.integers(0, 2**16))))
        # combine of per-slot gradient g=1 equals (sum w)/k = 1 exactly
        g = {"x": jnp.ones((n, r, 5))}
        out = agg.combine(g, w)
        np.testing.assert_allclose(np.asarray(out["x"]), 1.0, rtol=1e-4)

"""StragglerAggregator + RoundSpec property tests, including the
round-aware cluster state and adaptive scheduling paths."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (RoundSpec, StragglerAggregator, ec2_cluster,
                        scenario1, validate_to_matrix)


class TestRoundSpec:
    def test_validation(self):
        with pytest.raises(ValueError):
            RoundSpec(n=4, r=2, k=5)
        with pytest.raises(ValueError):
            RoundSpec(n=4, r=5, k=2)
        with pytest.raises(ValueError):
            RoundSpec(n=4, r=0, k=2)

    def test_to_matrix_schedules(self):
        for sched in ("cs", "ss", "block"):
            C = RoundSpec(n=6, r=3, k=4, schedule=sched).to_matrix()
            assert C.shape == (6, 3)
        C = RoundSpec(n=6, r=6, k=4, schedule="ra").to_matrix()
        assert C.shape == (6, 6)


class TestAggregator:
    def test_round_mask_and_combine(self):
        spec = RoundSpec(n=4, r=2, k=3, schedule="cs")
        agg = StragglerAggregator(spec, scenario1())
        w, t = agg.round_mask(jax.random.PRNGKey(0))
        assert w.shape == (4, 2)
        assert np.isclose(float(w.sum()), 3.0, atol=1e-5)
        grads = {"a": jnp.ones((4, 2, 3)), "b": jnp.ones((4, 2))}
        out = agg.combine(grads, w)
        # sum of weights / k = 1 -> combined grad of all-ones is 1
        np.testing.assert_allclose(np.asarray(out["a"]), 1.0, rtol=1e-5)
        np.testing.assert_allclose(np.asarray(out["b"]), 1.0, rtol=1e-5)

    def test_expected_completion_positive_and_orders(self):
        m = scenario1()
        fast = StragglerAggregator(RoundSpec(n=8, r=4, k=4), m)
        slow = StragglerAggregator(RoundSpec(n=8, r=4, k=8), m)
        key = jax.random.PRNGKey(1)
        tf = fast.expected_completion(key)
        ts = slow.expected_completion(key)
        assert 0 < tf < ts

    def test_cluster_state_persists_across_rounds(self):
        """A persistent-straggler process threads its state through
        round_mask calls: identical keys give different delays on
        consecutive rounds (state advanced), and straggling workers stay
        slow — consecutive per-round completion times are correlated."""
        spec = RoundSpec(n=8, r=2, k=6, schedule="cs")
        proc = ec2_cluster(8, spread=3.0, p_slow=0.3, persistence=0.98,
                           slow=20.0)
        agg = StragglerAggregator(spec, proc)
        key = jax.random.PRNGKey(0)
        _, t_a = agg.round_mask(key)
        # two aggregators, same init key -> same realization (determinism)
        agg2 = StragglerAggregator(spec, proc)
        _, t_a2 = agg2.round_mask(key)
        assert float(t_a) == float(t_a2)
        # regime state is carried and evolves across rounds
        states = []
        ts = []
        for i in range(60):
            ts.append(float(agg2.round_mask(jax.random.PRNGKey(i))[1]))
            states.append(np.asarray(agg2._state[0]))
        assert any(not np.array_equal(states[i], states[i + 1])
                   for i in range(len(states) - 1))
        # persistence: consecutive rounds' completion times correlate
        a, b = np.array(ts[:-1]), np.array(ts[1:])
        assert np.corrcoef(a, b)[0, 1] > 0.2

    def test_adaptive_round_api(self):
        spec = RoundSpec(n=8, r=2, k=6, schedule="cs")
        proc = ec2_cluster(8, spread=3.0, persistence=0.95, slow=10.0)
        agg = StragglerAggregator(spec, proc, adaptive=True)
        for i in range(4):
            C = agg.current_matrix()
            validate_to_matrix(C, 8)
            # rows are a permutation of the base schedule's rows
            assert sorted(map(tuple, C.tolist())) == \
                sorted(map(tuple, agg.base_C.tolist()))
            w, t = agg.round_mask(jax.random.PRNGKey(i))
            assert np.isclose(float(w.sum()), spec.k, atol=1e-4)
            assert float(t) > 0
        assert agg.scheduler.est is not None     # feedback accumulated

    def test_expected_completion_routes_through_engine(self):
        spec = RoundSpec(n=8, r=4, k=6)
        proc = ec2_cluster(8, spread=2.0, persistence=0.9)
        agg = StragglerAggregator(spec, proc)
        t = agg.expected_completion(trials=512)
        t2 = agg.expected_completion(trials=512, rounds=3)
        assert 0 < t and 0 < t2
        ad = StragglerAggregator(spec, proc, adaptive=True)
        t_ad = ad.expected_completion(trials=512)
        assert 0 < t_ad < t                      # adaptive helps here

    @settings(deadline=None, max_examples=20)
    @given(st.integers(2, 8), st.data())
    def test_property_combine_unbiased_weighting(self, n, data):
        r = data.draw(st.integers(1, n))
        k = data.draw(st.integers(1, n))
        sched = data.draw(st.sampled_from(["cs", "ss"]))
        spec = RoundSpec(n=n, r=r, k=k, schedule=sched)
        agg = StragglerAggregator(spec, scenario1())
        w, _ = agg.round_mask(jax.random.PRNGKey(data.draw(
            st.integers(0, 2**16))))
        # combine of per-slot gradient g=1 equals (sum w)/k = 1 exactly
        g = {"x": jnp.ones((n, r, 5))}
        out = agg.combine(g, w)
        np.testing.assert_allclose(np.asarray(out["x"]), 1.0, rtol=1e-4)

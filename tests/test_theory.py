"""Theorem 1 and lower-bound tests (paper Sec. III, V)."""
import numpy as np
import pytest
from scipy import stats

from repro.core import (cyclic_to_matrix, staircase_to_matrix, scenario1,
                        theorem1_tail_mc, theorem1_mean_mc,
                        theorem1_tail_r1_independent, sum_survival_grid,
                        mean_completion_time, simulate_completion,
                        simulate_lower_bound, TruncatedGaussianDelays)


@pytest.mark.parametrize("n,r,k,sched", [
    (4, 2, 3, "cs"), (4, 2, 4, "cs"), (5, 2, 4, "ss"),
    (6, 3, 4, "cs"), (6, 3, 6, "ss"), (5, 5, 2, "cs"),
])
def test_theorem1_identity_vs_direct_mc(n, r, k, sched):
    """The inclusion-exclusion assembly (eq. 7-8) must equal the direct
    k-th-order-statistic simulation when fed the same H_S estimates."""
    C = cyclic_to_matrix(n, r) if sched == "cs" else staircase_to_matrix(n, r)
    m = scenario1()
    t_thm = theorem1_mean_mc(C, m, k=k, tmax=4e-3, trials=6000)
    t_mc = mean_completion_time(C, m, k=k, trials=6000)
    assert abs(t_thm - t_mc) / t_mc < 0.03


def test_theorem1_tail_is_valid_survival():
    n, r, k = 5, 2, 4
    C = cyclic_to_matrix(n, r)
    tg = np.linspace(0, 4e-3, 128)
    tail = theorem1_tail_mc(C, scenario1(), tg, trials=6000, k=k)
    assert tail[0] > 0.999          # Pr{t_C > 0} = 1
    assert tail[-1] < 1e-3          # far tail -> 0
    assert (np.diff(tail) <= 1e-6).all()  # nonincreasing (within MC noise)


def test_theorem1_analytic_r1_independent():
    """r=1 with independent truncated-Gaussian delays: fully analytic tail
    via 1-D convolution vs Monte-Carlo simulation."""
    n, k = 6, 4
    m = scenario1()
    mu1, s1, a1 = m.mu1, m.sigma1, m.a1
    mu2, s2, a2 = m.mu2, m.sigma2, m.a2

    def tpdf(mu, sg, a):
        lo, hi = mu - a, mu + a
        d = stats.truncnorm((lo - mu) / sg, (hi - mu) / sg, loc=mu, scale=sg)
        return lambda t: d.pdf(t)

    tmax = 2e-3
    tg, surv = sum_survival_grid(tpdf(mu1, s1, a1), tpdf(mu2, s2, a2), tmax)
    tail = theorem1_tail_r1_independent([surv] * n, k)
    t_analytic = float(np.trapezoid(np.clip(tail, 0, 1), tg))
    C = cyclic_to_matrix(n, 1)
    t_mc = mean_completion_time(C, m, k, trials=20000)
    assert abs(t_analytic - t_mc) / t_mc < 0.02


def test_lower_bound_tight_for_r_equal_n_small_k():
    """Paper Fig. 7: SS coincides with the LB for small/medium k when r=n."""
    n = 8
    m = scenario1()
    C = staircase_to_matrix(n, n)
    for k in (2, 4):
        ub = mean_completion_time(C, m, k, trials=6000)
        lb = float(simulate_lower_bound(m, n, n, k, trials=6000).mean())
        assert (ub - lb) / lb < 0.08, (k, ub, lb)


def test_lower_bound_increases_with_k():
    m = scenario1()
    lbs = [float(simulate_lower_bound(m, 6, 3, k, trials=3000).mean())
           for k in range(1, 7)]
    assert all(a < b for a, b in zip(lbs, lbs[1:]))

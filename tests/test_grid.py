"""Streaming grid-sweep engine (repro.core.grid): CRN bit-exactness of
``stream_grid`` vs the per-cell ``sweep``/``sweep_rounds`` path, one
compile per shape bucket, the LRU executor cache, the versioned artifact,
and the ``repro.launch.grid`` CLI.

The multi-device legs need >= 4 devices; CI forces them on CPU with
``XLA_FLAGS=--xla_force_host_platform_device_count=4``.
"""
import json

import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (GridCell, GridResult, GridSpec, cache_stats,
                        clear_cache, cyclic_to_matrix, lb_spec,
                        scenario1, set_cache_capacity, staircase_to_matrix,
                        stream_grid, sweep, sweep_rounds, to_spec,
                        trial_keys)
from repro.core import montecarlo as mc
from repro.core.grid import _family_spec
from repro.launch import grid as grid_cli

multidev = pytest.mark.skipif(
    jax.device_count() < 4,
    reason="needs 4 devices (XLA_FLAGS=--xla_force_host_platform_device_count=4)")

MODEL = scenario1()


# ---------------------------------------------------------------------------
# GridSpec enumeration
# ---------------------------------------------------------------------------

class TestGridSpec:
    def test_cells_skip_infeasible_combinations(self):
        gs = GridSpec(n=8, families=("cs", "ra", "pc", "pcmm"),
                      loads=(1, 2, 8), messages=(None, 4),
                      comm_eps=(0.0, 0.1), trials=100)
        names = [c.name for c in gs.cells(MODEL)]
        assert "ra/r8" in names and "ra/r2" not in names   # RA needs r == n
        assert "cs/r1/m4" not in names                     # budget > load
        assert "pc/r2" in names
        assert not any(n_.startswith("pc/") and "m4" in n_ for n_ in names)
        assert not any(n_.startswith("pc/") and "eps" in n_ for n_ in names)
        assert "pcmm/r1" not in names                      # below 2n-1
        assert len(names) == len(set(names))

    def test_empty_grid_rejected(self):
        gs = GridSpec(n=8, families=("pcmm",), loads=(1,), trials=10)
        with pytest.raises(ValueError, match="empty"):
            gs.cells(MODEL)
        with pytest.raises(ValueError, match="unknown families"):
            GridSpec(n=8, families=("nope",))

    def test_json_round_trip(self):
        gs = GridSpec(n=12, families=("ss", "lb"), loads=(2, 3),
                      messages=(None, 2), comm_eps=(0.0, 0.01), ks=(None, 4),
                      trials=777, seed=9, chunk=100)
        assert GridSpec.from_json(gs.to_json()) == gs
        with pytest.raises(ValueError, match="newer"):
            GridSpec.from_json({"version": 999, "n": 4})

    def test_cell_validation(self):
        sp = to_spec("x", cyclic_to_matrix(4, 2))
        with pytest.raises(ValueError, match="at least one spec"):
            GridCell("empty", (), 4, MODEL)
        with pytest.raises(ValueError, match="rounds cells"):
            GridCell("half", (sp,), 4, MODEL, rounds=3)   # k missing


# ---------------------------------------------------------------------------
# bit-exactness vs the per-cell path (the CRN contract)
# ---------------------------------------------------------------------------

def _assert_stream_matches_per_cell(cells, devices):
    res = stream_grid(cells, devices=devices)
    for c in cells:
        got = res.cell(c.name)
        if c.is_rounds:
            ref = sweep_rounds(c.specs, c.model, c.n, rounds=c.rounds,
                               k=c.k, trials=c.trials, seed=c.seed,
                               chunk=c.chunk, deadline=c.deadline,
                               deadline_policy=c.deadline_policy,
                               devices=devices)
            for sp in c.specs:
                np.testing.assert_array_equal(got["per_round"][sp.name],
                                              ref.per_round[sp.name])
                np.testing.assert_array_equal(got["wallclock"][sp.name],
                                              ref.wallclock[sp.name])
                np.testing.assert_array_equal(
                    got["wallclock_stderr"][sp.name],
                    ref.wallclock_stderr[sp.name])
                if c.deadline is not None:
                    for key in ("realized_k", "missed", "stale", "khist"):
                        np.testing.assert_array_equal(
                            got["degradation"][sp.name][key],
                            ref.degradation[sp.name][key])
        else:
            ref = sweep(c.specs, c.model, c.n, trials=c.trials, seed=c.seed,
                        chunk=c.chunk, ks=c.ks, devices=devices)
            for sp in c.specs:
                np.testing.assert_array_equal(
                    got["means"][sp.name], np.atleast_1d(ref.means[sp.name]))
                np.testing.assert_array_equal(
                    got["stderr"][sp.name],
                    np.atleast_1d(ref.stderr[sp.name]))
    return res


def _random_cells(data, n):
    """A random mixed cell set: dense/ragged TO schemes x message budgets x
    comm_eps x all-k/single-k, plus optionally a rounds cell."""
    cells = []
    n_cells = data.draw(st.integers(2, 4), label="n_cells")
    for i in range(n_cells):
        r = data.draw(st.integers(2, n), label=f"r{i}")
        m = data.draw(st.sampled_from([None, 1, 2]), label=f"m{i}")
        eps = data.draw(st.sampled_from([0.0, 0.02]), label=f"eps{i}")
        ragged = data.draw(st.booleans(), label=f"ragged{i}")
        if ragged and r >= 2:
            loads = [data.draw(st.integers(1, r), label=f"load{i}_{w}")
                     for w in range(n)]
            loads[0] = r               # keep the max load at r
            sp = to_spec("s", cyclic_to_matrix(n, r), messages=m,
                         loads=loads, comm_eps=eps)
            ks = 1                     # ragged coverage: k=1 always finite
        else:
            sp = to_spec("s", staircase_to_matrix(n, r), messages=m,
                         comm_eps=eps)
            ks = data.draw(st.sampled_from([None, n // 2]), label=f"k{i}")
        cells.append(GridCell(f"cell{i}", (sp, lb_spec(r, messages=m)), n,
                              MODEL, trials=250, seed=i % 2, ks=ks))
    if data.draw(st.booleans(), label="rounds_cell"):
        deadline = data.draw(st.sampled_from([None, 3.0]), label="deadline")
        cells.append(GridCell(
            "rcell", (to_spec("s", cyclic_to_matrix(n, 2)),), n, MODEL,
            trials=60, seed=1, rounds=2, k=2, deadline=deadline,
            deadline_policy="wait" if deadline is None else "close_partial"))
    return cells


class TestBitExact:
    @settings(deadline=None, max_examples=8)
    @given(st.data())
    def test_random_cell_set_matches_per_cell_single_device(self, data):
        _assert_stream_matches_per_cell(_random_cells(data, n=5), devices=1)

    @multidev
    @settings(deadline=None, max_examples=4)
    @given(st.data())
    def test_random_cell_set_matches_per_cell_four_devices(self, data):
        _assert_stream_matches_per_cell(_random_cells(data, n=5), devices=4)

    @multidev
    def test_stream_grid_device_invariant(self):
        cells = GridSpec(n=6, families=("cs", "ss", "lb", "pc"),
                         loads=(2, 3), messages=(None, 2),
                         trials=400, seed=0).cells(MODEL)
        r1 = stream_grid(cells, devices=1)
        r4 = stream_grid(cells, devices=4)
        for c in cells:
            for sp in c.specs:
                np.testing.assert_array_equal(
                    r1.cell(c.name)["means"][sp.name],
                    r4.cell(c.name)["means"][sp.name])
                np.testing.assert_array_equal(
                    r1.cell(c.name)["stderr"][sp.name],
                    r4.cell(c.name)["stderr"][sp.name])

    def test_fusion_groups_by_draw_coordinates(self):
        # same (n, r_max, trials, seed): one fused dispatch; different
        # seed: its own dispatch
        sp = to_spec("x", cyclic_to_matrix(6, 2))
        cells = [GridCell("a", (sp,), 6, MODEL, trials=200, seed=0),
                 GridCell("b", (lb_spec(2),), 6, MODEL, trials=200, seed=0),
                 GridCell("c", (sp,), 6, MODEL, trials=200, seed=1)]
        res = stream_grid(cells)
        assert res.meta["fused_dispatches"] == 2
        ref = sweep([sp], MODEL, 6, trials=200, seed=1)
        np.testing.assert_array_equal(res.cell("c")["means"]["x"],
                                      ref.means["x"])

    def test_duplicate_names_and_bad_pipeline_rejected(self):
        sp = to_spec("x", cyclic_to_matrix(4, 2))
        cell = GridCell("a", (sp,), 4, MODEL, trials=50)
        with pytest.raises(ValueError, match="duplicate"):
            stream_grid([cell, cell])
        with pytest.raises(ValueError, match="pipeline"):
            stream_grid([cell], pipeline=0)
        with pytest.raises(ValueError, match="at least one"):
            stream_grid([])


# ---------------------------------------------------------------------------
# executor bucketing: one compile per shape bucket, LRU bounds
# ---------------------------------------------------------------------------

class TestBucketedCache:
    def test_one_compile_per_shape_bucket(self):
        # 8 cells, 2 shape buckets (r_max 2 and 3) — exactly 2 retraces
        cells = []
        for i, (r, eps) in enumerate([(2, 0.0), (2, 0.1), (3, 0.0),
                                      (3, 0.1)]):
            for fam, build in (("cs", cyclic_to_matrix),
                               ("ss", staircase_to_matrix)):
                cells.append(GridCell(
                    f"{fam}{i}", (to_spec(fam, build(6, r), comm_eps=eps),),
                    6, MODEL, trials=150, seed=0))
        clear_cache()
        before = cache_stats()
        res = stream_grid(cells)
        after = cache_stats()
        assert res.meta["buckets"] == 2
        assert after["traces"] - before["traces"] <= res.meta["buckets"]
        assert after["exec"]["misses"] - before["exec"]["misses"] == 2
        # the whole grid again: pure cache hits, zero new traces
        stream_grid(cells)
        final = cache_stats()
        assert final["traces"] == after["traces"]
        assert final["exec"]["misses"] == after["exec"]["misses"]
        assert final["exec"]["hits"] > after["exec"]["hits"]

    def test_renamed_specs_share_the_bucket(self):
        clear_cache()
        C = cyclic_to_matrix(6, 2)
        before = cache_stats()["traces"]
        sweep([to_spec("alpha", C)], MODEL, 6, trials=100, seed=0)
        sweep([to_spec("omega", C)], MODEL, 6, trials=100, seed=0)
        sweep([to_spec("x", staircase_to_matrix(6, 2), comm_eps=0.3)],
              MODEL, 6, trials=100, seed=0)
        assert cache_stats()["traces"] - before == 1

    def test_lru_capacity_bounds_and_evicts(self):
        clear_cache()
        set_cache_capacity(2)
        try:
            for r in (2, 3, 4):        # 3 distinct buckets, capacity 2
                sweep([lb_spec(r)], MODEL, 6, trials=60, seed=0)
            stats = cache_stats()["exec"]
            assert stats["size"] <= 2
            assert stats["evictions"] >= 1
            assert stats["compile_s"] > 0.0
            with pytest.raises(ValueError, match="capacity"):
                set_cache_capacity(0)
        finally:
            set_cache_capacity(128)
            clear_cache()

    def test_trial_keys_twin(self):
        # _padded_keys stays the host-side reference twin of the device-side
        # fold_in derivation: same keys, pad repeats the last trial's key
        keys = np.asarray(trial_keys(7, 5))
        padded = np.asarray(mc._padded_keys(7, 5, 8))
        assert np.array_equal(padded[:5], keys)
        assert np.array_equal(padded[5:], np.broadcast_to(keys[-1], (3, 2)))


# ---------------------------------------------------------------------------
# artifact + CLI
# ---------------------------------------------------------------------------

class TestArtifact:
    def test_result_round_trip(self, tmp_path):
        cells = [
            GridCell("sw", (to_spec("x", cyclic_to_matrix(5, 2)),), 5,
                     MODEL, trials=120, seed=0),
            GridCell("ro", (to_spec("x", cyclic_to_matrix(5, 2)),), 5,
                     MODEL, trials=40, seed=0, rounds=2, k=3, deadline=3.0,
                     deadline_policy="close_partial"),
        ]
        res = stream_grid(cells)
        path = str(tmp_path / "grid.json")
        res.save(path)
        back = GridResult.load(path)
        assert set(back.cells) == {"sw", "ro"}
        np.testing.assert_array_equal(back.means("sw", "x"),
                                      res.means("sw", "x"))
        np.testing.assert_array_equal(
            back.cell("ro")["degradation"]["x"]["khist"],
            res.cell("ro")["degradation"]["x"]["khist"])
        assert back.meta["cells"] == 2
        assert back.cells_per_sec > 0

    def test_load_rejects_foreign_and_newer(self, tmp_path):
        p = str(tmp_path / "x.json")
        with open(p, "w") as fh:
            json.dump({"kind": "other"}, fh)
        with pytest.raises(ValueError, match="not a grid-result"):
            GridResult.load(p)
        with open(p, "w") as fh:
            json.dump({"kind": "grid-result", "version": 999, "cells": {}},
                      fh)
        with pytest.raises(ValueError, match="newer"):
            GridResult.load(p)

    def test_cli_writes_consumable_artifact(self, tmp_path, capsys):
        out = str(tmp_path / "out" / "grid.json")
        rc = grid_cli.main(["--n", "5", "--families", "cs", "lb",
                            "--loads", "2", "--trials", "200",
                            "--out", out])
        assert rc == 0
        res = GridResult.load(out)
        assert res.meta["cells"] == 2
        assert res.meta["model"] == "scenario1"
        assert res.meta["spec"]["n"] == 5
        # the artifact's stats are the engine's own (CRN contract)
        ref = sweep([_family_spec("cs", 5, 2, None, 0.0, 0)], MODEL, 5,
                    trials=200, seed=0)
        np.testing.assert_array_equal(res.means("cs/r2", "cs"),
                                      ref.means["cs"])
        assert "cells/s" in capsys.readouterr().out

    def test_cli_spec_file(self, tmp_path):
        spec_path = str(tmp_path / "spec.json")
        gs = GridSpec(n=4, families=("ss",), loads=(2,), trials=100, seed=2)
        with open(spec_path, "w") as fh:
            json.dump(gs.to_json(), fh)
        out = str(tmp_path / "res.json")
        assert grid_cli.main(["--spec", spec_path, "--out", out]) == 0
        res = GridResult.load(out)
        assert res.meta["spec"] == gs.to_json()
        assert list(res.cells) == ["ss/r2"]
